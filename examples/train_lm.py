"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the reduced-but-real stack: data pipeline -> transformer ->
AdamW + cosine -> checkpointing, with resume support.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.common.config import LMConfig
from repro.data.pipeline import synthetic_lm_batches
from repro.models import transformer as T
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import cosine_schedule


def small_lm() -> LMConfig:
    """~100M params: 8L x 512d x 8H, vocab 32k."""
    return LMConfig(
        name="demo-100m", family="lm-dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=32000,
        max_seq_len=512)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", type=str, default="/tmp/repro-ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = small_lm()
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params")

    make_batch = synthetic_lm_batches(cfg.vocab_size, args.batch,
                                      args.seq, seed=0)
    result = run_training(
        lambda p, b: T.loss_fn(p, b, cfg),
        params, make_batch,
        LoopConfig(max_steps=args.steps, ckpt_every=100,
                   ckpt_dir=args.ckpt, log_every=20,
                   n_microbatches=2),
        resume=args.resume,
        lr_schedule=cosine_schedule(3e-4, warmup=20,
                                    total=args.steps))
    print(f"finished at step {result.final_step}: "
          f"loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f} "
          f"({result.wall_time_s:.1f}s, "
          f"{result.straggler_steps} straggler steps)")
    assert result.losses[-1] < result.losses[0]


if __name__ == "__main__":
    main()
