"""Live ingestion during serving: grow the corpus without stalling it.

The growing-corpus loop the paper pitches, run end to end: a serving
index answers query batches while an ``IngestService`` streams a
document burst in — chunking, embedding, LSH-routing and committing in
small per-tick quanta interleaved between query batches (the same
one-step-per-refresh discipline the store uses for compaction and
resharding).  Segment summarization lands batched through
``Summarizer.summarize_batch`` and the content-keyed summary cache,
and the final index is bitwise what a synchronous ``insert_docs``
would have produced — the example verifies that against a twin at the
end, and prints ``index_report()["ingest"]`` so you can see queue
depth, burst commits, and summary-cache savings.

    PYTHONPATH=src python examples/live_ingest.py
"""
from repro.common.config import EraRAGConfig
from repro.core.erarag import EraRAG
from repro.data.corpus import SyntheticCorpus
from repro.embed.hashing import HashingEmbedder
from repro.ingest import IngestService
from repro.serving.rag_pipeline import RAGPipeline


def main() -> None:
    cfg = EraRAGConfig(embed_dim=128, n_hyperplanes=10, s_min=4,
                       s_max=12, max_layers=3, chunk_tokens=32,
                       top_k=8, token_budget=1024,
                       ingest_docs_per_tick=4, ingest_embed_batch=16)
    corpus = SyntheticCorpus.generate(n_docs=60, n_topics=6, seed=0)
    base, burst = corpus.docs[:40], corpus.docs[40:]
    questions = [qa.question for qa in corpus.qa][:12]

    rag = EraRAG(cfg, HashingEmbedder(dim=cfg.embed_dim))
    rag.insert_docs(base)
    rag.store.refresh()
    pipe = RAGPipeline(rag)
    svc = IngestService(rag)
    pipe.attach_ingest(svc)

    # the serving loop: one ingest tick between query batches — an
    # insert burst never stalls retrieval, it just takes a few ticks
    svc.submit_many(burst)
    qi = 0
    while not svc.idle:
        stage = svc.tick()
        block = questions[qi % len(questions): qi % len(questions) + 4]
        answers = pipe.answer_batch(block or questions[:4])
        qi += 4
        print(f"tick={stage:<6s} pending={svc.pending_docs:3d} "
              f"index={rag.store.size:4d} rows "
              f"answered={len(answers)}")

    # background ingest is bitwise a synchronous insert of the burst
    twin = EraRAG(cfg, HashingEmbedder(dim=cfg.embed_dim))
    twin.insert_docs(base)
    for kind, payload in svc.committed_ops:
        (twin.insert_docs if kind == "insert"
         else twin.remove_docs)(payload)
    assert list(rag.graph.nodes) == list(twin.graph.nodes)
    for q in questions[:4]:
        a, b = rag.query(q), twin.query(q)
        assert [(h.node_id, h.score) for h in a.hits] == \
            [(h.node_id, h.score) for h in b.hits]
    print("\nbitwise parity with synchronous insert_docs: OK")

    ingest_report = pipe.index_report()["ingest"]
    print("ingest report:")
    for key, val in ingest_report.items():
        print(f"  {key}: {val}")


if __name__ == "__main__":
    main()
