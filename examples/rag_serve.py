"""RAG serving: EraRAG retrieval + batched LM decode engine.

Builds the index, serves batched QA requests through the engine
(slot-based continuous batching over a shared KV cache), and then
demonstrates an incremental corpus update without taking the service
down — the paper's deployment story end-to-end.

    PYTHONPATH=src python examples/rag_serve.py
"""
import jax

from repro.common.config import EraRAGConfig, LMConfig
from repro.core.erarag import EraRAG
from repro.data.corpus import SyntheticCorpus
from repro.embed.hashing import HashingEmbedder
from repro.models import transformer as T
from repro.serving.engine import Engine, EngineConfig
from repro.serving.rag_pipeline import RAGPipeline


def tiny_reader() -> LMConfig:
    return LMConfig(name="reader", family="lm-dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                    vocab_size=32000, max_seq_len=512)


def main() -> None:
    cfg = EraRAGConfig(embed_dim=128, n_hyperplanes=10, s_min=4,
                       s_max=12, max_layers=3, chunk_tokens=32,
                       top_k=8, token_budget=512)
    rag = EraRAG(cfg, HashingEmbedder(dim=cfg.embed_dim))
    corpus = SyntheticCorpus.generate(n_docs=40, n_topics=5, seed=0)
    init, rounds = corpus.growth_rounds(0.6, 2)
    rag.insert_docs(init)
    print(f"index: {len(rag.graph.nodes)} nodes, "
          f"{rag.graph.n_layers} layers")

    # batched decode engine over an (untrained) tiny reader LM: the
    # engine mechanics (slots, prefill, per-slot cache, eviction) are
    # what this example exercises; examples/train_lm.py trains weights.
    lm = tiny_reader()
    params, _ = T.init_params(lm, jax.random.PRNGKey(0))
    engine = Engine(lm, params, EngineConfig(max_batch=4,
                                             max_seq_len=256,
                                             max_new_tokens=8))
    # deterministic extractive reader answers; engine generates
    # alongside to show the serving path
    pipeline = RAGPipeline(rag)
    questions = [qa for qa in corpus.qa if qa.kind == "detailed"][:6]
    for qa in questions:
        ans = pipeline.answer(qa.question)
        rid = engine.submit(f"Context: {ans.context[:200]} "
                            f"Q: {qa.question}")
        mark = "OK " if qa.answer in ans.answer else "MISS"
        print(f"[{mark}] {qa.question} -> {ans.answer}")
    engine.run_until_done()
    print(f"engine drained: {len(engine._results)} generations")

    # live update: corpus grows while serving continues
    rep = rag.insert_docs(rounds[0])
    print(f"live update: +{rep.n_new_chunks} chunks, "
          f"{rep.n_resummarized} re-summaries, index now "
          f"{len(rag.graph.nodes)} nodes")
    ans = pipeline.answer(questions[0].question)
    print(f"post-update query still serves: {ans.answer!r}")


if __name__ == "__main__":
    main()
